"""Multi-tenant substrate benchmark: shared TenantRouter vs per-tenant silos.

The same Zipf-over-tenants request mix (``serving/simulator.py``) is served
two ways against identically built per-tenant corpora:

  shared  one :class:`~repro.core.tenant.TenantRouter`: every tenant's
          clusters behind one storage backend, ONE cost-aware LFU cache
          (full byte budget, global eviction), and mixed batches fused
          into a single cross-tenant slab launch per representation
  silo    the status quo: one standalone :class:`EdgeRAGIndex` per tenant,
          each with 1/T of the cache budget, each batch split per tenant
          and served as T separate small ``search_batch`` calls

Per-query (ids, scores) are asserted BITWISE IDENTICAL across arms — the
slab virt matrix masks non-member rows, so fusing tenants into one launch
cannot perturb anyone's results, and cache/storage/regen tiers all produce
value-identical payloads.  The throughput comparison is therefore pure
substrate: the shared arm amortizes per-call fixed costs (probe dispatch,
slab pack setup, one fused top-k instead of T small ones) across the whole
mixed batch.  The shared cache additionally follows the Zipf skew — hot
tenants borrow budget cold tenants aren't using — which silos cannot.

NOISY NEIGHBOR: an open-loop two-tenant arm (big tenant floods at ~3x
device capacity, small tenant trickles) runs through
:class:`~repro.serving.scheduler.RequestScheduler` twice: admission off,
then :class:`~repro.serving.scheduler.TokenBucketAdmission` at each
tenant's fair share.  Without admission the big tenant's backlog queues the
small tenant into oblivion; with it, over-share big requests (and requests
whose queue wait already blew their SLO) are shed and the small tenant's
p99 TTFT collapses back to ~service time.

Acceptance (full scale): shared-substrate QPS >= 1.3x silo at >= 8
tenants, ids bitwise identical across arms, a one-tenant router bitwise
identical to a standalone index, and admission control cutting the small
tenant's p99 TTFT.  At ``--quick`` scale the CI smoke lane enforces only
"shared not slower" plus both bitwise criteria.

``python -m benchmarks.multi_tenant [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex, TenantRouter
from repro.data import generate_dataset
from repro.serving.scheduler import RequestScheduler, TokenBucketAdmission
from repro.serving.simulator import zipf_over_tenants

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_multi_tenant.json")

DIM = 48
K = 5
NPROBE = 6
BATCH = 16               # mixed-tenant closed-loop batch size
ZIPF_A = 1.2             # tenant-mix skew (rank 0 hottest)
CACHE_TOTAL = 1 << 22    # 4 MiB: shared budget == sum of silo budgets


def _tenant_id(rank: int) -> str:
    return f"t{rank}"


def _make_corpora(n_tenants: int, n_records: int, nq: int) -> List:
    return [generate_dataset(n_records=n_records, dim=DIM,
                             n_topics=max(8, n_records // 50),
                             n_queries=nq, seed=100 + t)
            for t in range(n_tenants)]


def _slo(ds, nlist: int, cost) -> float:
    mean_cluster_chars = sum(len(t) for t in ds.texts) / nlist
    return cost.embed_latency(int(1.15 * mean_cluster_chars))


def _build_router(corpora, cost, nlist: int) -> TenantRouter:
    router = TenantRouter(DIM, cost, cache_bytes=CACHE_TOTAL)
    for t, ds in enumerate(corpora):
        ix = router.create_tenant(_tenant_id(t), ds.embedder, ds.get_chunks,
                                  slo_s=_slo(ds, nlist, cost),
                                  maintenance="deferred")
        ix.build(ds.chunk_ids, ds.texts, nlist=nlist,
                 embeddings=ds.embeddings, seed=1)
    return router


def _build_silos(corpora, cost, nlist: int) -> List[EdgeRAGIndex]:
    # each silo gets an equal static slice of the same total cache budget
    out = []
    for ds in corpora:
        ix = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost,
                          slo_s=_slo(ds, nlist, cost),
                          cache_bytes=CACHE_TOTAL // len(corpora),
                          maintenance="deferred")
        ix.build(ds.chunk_ids, ds.texts, nlist=nlist,
                 embeddings=ds.embeddings, seed=1)
        out.append(ix)
    return out


def _mixed_batches(corpora, trace) -> List[Tuple[List[int], np.ndarray]]:
    """Group the trace into batches of BATCH: (tenant ranks, query embs).
    Query index within a tenant cycles through its corpus queries."""
    per_tenant_count = [0] * len(corpora)
    batches = []
    ids = trace.tenant_ids
    for start in range(0, len(ids) - len(ids) % BATCH, BATCH):
        ranks = [int(t) for t in ids[start:start + BATCH]]
        embs = []
        for t in ranks:
            ds = corpora[t]
            qi = per_tenant_count[t] % len(ds.query_embs)
            per_tenant_count[t] += 1
            embs.append(ds.query_embs[qi])
        batches.append((ranks, np.stack(embs)))
    return batches


TIMED_PASSES = 3         # best-of-N timed passes (steady state, less noise)


def run_shared(router: TenantRouter, batches) -> Dict:
    """Closed-loop mixed batches through the fused router; one untimed
    warm-up pass (cache fill), then best-of-``TIMED_PASSES`` timed passes.
    The first timed pass's results are the bit-identity reference."""
    for ranks, embs in batches:
        router.search_batch(embs, K, NPROBE,
                            tenants=[_tenant_id(t) for t in ranks])
    all_ids, all_vals, edge_s = [], [], 0.0
    wall = float("inf")
    for p in range(TIMED_PASSES):
        t0 = time.perf_counter()
        for ranks, embs in batches:
            ids, vals, lats = router.search_batch(
                embs, K, NPROBE, tenants=[_tenant_id(t) for t in ranks])
            if p == 0:
                all_ids.append(ids)
                all_vals.append(vals)
                edge_s += sum(lat.retrieval_s for lat in lats)
        wall = min(wall, time.perf_counter() - t0)
    nq = sum(len(r) for r, _ in batches)
    return {"wall_s": wall, "qps": nq / wall, "edge_retrieval_s": edge_s,
            "cache_hit_rate": router.cache.hit_rate,
            "ids": all_ids, "vals": all_vals}


def run_silo(silos: List[EdgeRAGIndex], batches) -> Dict:
    """The same batches served as T per-tenant sub-batches per batch
    (order within a tenant preserved — the comparison the fused slab
    launch must match bitwise)."""
    def serve_batch(ranks, embs, collect=None):
        total_edge = 0.0
        by_tenant: Dict[int, List[int]] = {}
        for pos, t in enumerate(ranks):
            by_tenant.setdefault(t, []).append(pos)
        out_ids = np.empty((len(ranks), K), np.int64)
        out_vals = np.empty((len(ranks), K), np.float32)
        for t, positions in by_tenant.items():
            sub = np.ascontiguousarray(embs[positions])
            ids, vals, lats = silos[t].search_batch(sub, K, NPROBE)
            out_ids[positions] = ids
            out_vals[positions] = vals
            total_edge += sum(lat.retrieval_s for lat in lats)
        if collect is not None:
            collect[0].append(out_ids)
            collect[1].append(out_vals)
        return total_edge

    for ranks, embs in batches:                      # warm-up
        serve_batch(ranks, embs)
    all_ids: List[np.ndarray] = []
    all_vals: List[np.ndarray] = []
    edge_s = 0.0
    wall = float("inf")
    for p in range(TIMED_PASSES):
        t0 = time.perf_counter()
        for ranks, embs in batches:
            got = serve_batch(ranks, embs,
                              collect=(all_ids, all_vals) if p == 0
                              else None)
            if p == 0:
                edge_s += got
        wall = min(wall, time.perf_counter() - t0)
    nq = sum(len(r) for r, _ in batches)
    hits = sum(ix.cache.hits for ix in silos)
    misses = sum(ix.cache.misses for ix in silos)
    return {"wall_s": wall, "qps": nq / wall, "edge_retrieval_s": edge_s,
            "cache_hit_rate": hits / (hits + misses) if hits + misses
            else 0.0,
            "ids": all_ids, "vals": all_vals}


def single_tenant_bitwise(corpora, cost, nlist: int) -> bool:
    """A one-tenant router must replay a standalone index exactly —
    ids, scores, AND modeled retrieval charges."""
    ds = corpora[0]
    sa = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost,
                      slo_s=_slo(ds, nlist, cost), cache_bytes=CACHE_TOTAL,
                      maintenance="deferred")
    sa.build(ds.chunk_ids, ds.texts, nlist=nlist, embeddings=ds.embeddings,
             seed=1)
    router = TenantRouter(DIM, cost, cache_bytes=CACHE_TOTAL)
    ix = router.create_tenant("only", ds.embedder, ds.get_chunks,
                              slo_s=_slo(ds, nlist, cost))
    ix.build(ds.chunk_ids, ds.texts, nlist=nlist, embeddings=ds.embeddings,
             seed=1)
    qc = [int(c) for c in ds.query_chars]
    for _ in range(2):          # cold pass + warm pass must both match
        ids0, vals0, lats0 = sa.search_batch(ds.query_embs, K, NPROBE, qc)
        ids1, vals1, lats1 = router.search_batch(ds.query_embs, K, NPROBE,
                                                 qc, tenants="only")
        if not (np.array_equal(ids0, ids1)
                and np.array_equal(vals0, vals1)):
            return False
        for l0, l1 in zip(lats0, lats1):
            if l0.retrieval_s != l1.retrieval_s:
                return False
    return sa.threshold.threshold == ix.threshold.threshold


def noisy_neighbor(corpora, cost, nlist: int, *, n_big: int, n_small: int,
                   admission_on: bool) -> Dict:
    """Open-loop two-tenant arm on the modeled clock: the big tenant
    floods at ~3x device capacity, the small tenant trickles well under
    its fair share.  Service = the request's real modeled retrieval +
    prefill through a fresh shared router."""
    router = _build_router(corpora[:2], cost, nlist)
    prefill_s = cost.prefill_latency(256)
    ds_big, ds_small = corpora[0], corpora[1]

    # calibrate one service time so arrival rates mean something
    _, _, lats = router.search_batch(ds_big.query_embs[:1], K, NPROBE,
                                     tenants=_tenant_id(0))
    service_est = lats[0].retrieval_s + prefill_s
    slo_s = 6.0 * service_est
    fair = 0.5 / service_est        # half of device throughput each
    admission = (TokenBucketAdmission({_tenant_id(0): fair,
                                       _tenant_id(1): fair}, burst=4.0)
                 if admission_on else None)
    sched = RequestScheduler(admission=admission)
    for i in range(n_big):          # 3x capacity: backlog grows linearly
        sched.submit(i * service_est / 3.0, query_emb=ds_big.query_embs[
            i % len(ds_big.query_embs)], slo_s=slo_s, tenant=_tenant_id(0))
    for j in range(n_small):        # ~0.1x capacity: well under fair share
        sched.submit(j * service_est * 10.0,
                     query_emb=ds_small.query_embs[
                         j % len(ds_small.query_embs)],
                     slo_s=slo_s, tenant=_tenant_id(1))

    def serve(req):
        _, _, lats = router.search_batch(
            np.asarray(req.query_emb)[None], K, NPROBE,
            tenants=[req.tenant])
        return lats[0].retrieval_s + prefill_s

    sched.run(serve)
    out: Dict[str, Dict] = {"slo_s": slo_s, "service_est_s": service_est,
                            "outcomes": sched.outcome_counts()}
    for t, name in ((_tenant_id(0), "big"), (_tenant_id(1), "small")):
        reqs = [r for r in sched.completed if r.tenant == t]
        served = [r.latency_s for r in reqs if not r.rejected]
        out[name] = {
            "n": len(reqs), "n_served": len(served),
            "n_rejected": sum(r.rejected for r in reqs),
            "p50_ttft_s": float(np.percentile(served, 50)) if served
            else float("inf"),
            "p99_ttft_s": float(np.percentile(served, 99)) if served
            else float("inf"),
            "slo_hit_rate": (sum(r.slo_met for r in reqs) / len(reqs))
            if reqs else 0.0,
        }
    return out


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_tenants = 4 if quick else 8
    n_records = 220 if quick else 500
    nq = 12 if quick else 16
    n_requests = 160 if quick else 768
    nlist = max(8, n_records // 30)
    cost = EdgeCostModel()
    corpora = _make_corpora(n_tenants, n_records, nq)
    trace = zipf_over_tenants(n_tenants, n_requests, zipf_a=ZIPF_A, seed=7)
    batches = _mixed_batches(corpora, trace)

    router = _build_router(corpora, cost, nlist)
    silos = _build_silos(corpora, cost, nlist)
    shared = run_shared(router, batches)
    silo = run_silo(silos, batches)

    ids_identical = all(
        np.array_equal(a, b) and np.array_equal(va, vb)
        for a, b, va, vb in zip(shared.pop("ids"), silo.pop("ids"),
                                shared.pop("vals"), silo.pop("vals")))
    one_tenant_ok = single_tenant_bitwise(corpora, cost, nlist)
    qps_ratio = shared["qps"] / silo["qps"]

    nn_off = noisy_neighbor(corpora, cost, nlist,
                            n_big=60 if quick else 240,
                            n_small=12 if quick else 40,
                            admission_on=False)
    nn_on = noisy_neighbor(corpora, cost, nlist,
                           n_big=60 if quick else 240,
                           n_small=12 if quick else 40,
                           admission_on=True)
    admission_helps = (nn_on["small"]["p99_ttft_s"]
                       < nn_off["small"]["p99_ttft_s"])

    emit("multi_tenant.shared", shared["wall_s"] * 1e6,
         f"qps={shared['qps']:.1f} "
         f"cache_hit={shared['cache_hit_rate']:.3f}")
    emit("multi_tenant.silo", silo["wall_s"] * 1e6,
         f"qps={silo['qps']:.1f} cache_hit={silo['cache_hit_rate']:.3f}")
    emit("multi_tenant.speedup", qps_ratio * 1e6,
         f"qps_ratio={qps_ratio:.2f} ids_identical={ids_identical} "
         f"single_tenant_bitwise={one_tenant_ok}")
    emit("multi_tenant.admission",
         nn_on["small"]["p99_ttft_s"] * 1e6,
         f"small_p99_off={nn_off['small']['p99_ttft_s']:.3f}s "
         f"small_p99_on={nn_on['small']['p99_ttft_s']:.3f}s "
         f"rejected={nn_on['outcomes']['rejected']}")

    results = {
        "n_tenants": n_tenants, "n_records_per_tenant": n_records,
        "nlist": nlist, "dim": DIM, "k": K, "nprobe": NPROBE,
        "batch": BATCH, "n_requests": len(batches) * BATCH,
        "zipf_a": ZIPF_A, "cache_total_bytes": CACHE_TOTAL,
        "tenant_request_counts": {str(t): c
                                  for t, c in trace.counts().items()},
        "shared": shared,
        "silo": silo,
        "qps_ratio": qps_ratio,
        "ids_identical": ids_identical,
        "single_tenant_bitwise": one_tenant_ok,
        "noisy_neighbor": {"admission_off": nn_off, "admission_on": nn_on},
        "criteria": {
            # full-scale targets; the CI smoke lane (--quick) enforces
            # only shared_not_slower + the two bitwise criteria
            "shared_qps_1_3x": qps_ratio >= 1.3,
            "n_tenants_8": n_tenants >= 8,
            "shared_not_slower": qps_ratio >= 1.0,
            "ids_identical": ids_identical,
            "single_tenant_bitwise": one_tenant_ok,
            "admission_cuts_small_p99": admission_helps,
        },
    }
    ok = all(results["criteria"].values())
    print(f"# shared >= 1.3x silo at >= 8 tenants, bitwise identity, "
          f"admission protects the small tenant: "
          f"{'PASS' if ok else 'FAIL'}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
