"""Fig. 5 analogue: distribution of per-cluster embedding-generation cost
for an nq-like corpus — REAL index build (k-means on synthetic embeddings),
cost-model latencies.  The paper's claim: majority < 500 ms, tail > 2 s."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data.synthetic import scaled_beir


def run(n_records: int = 4000):
    ds = scaled_beir("nq", n_records=n_records, n_queries=10)
    cost = EdgeCostModel()
    er = EdgeRAGIndex(ds.embeddings.shape[1], ds.embedder, ds.get_chunks,
                      cost, slo_s=1.5)
    er.build(ds.chunk_ids, ds.texts, nlist=max(64, n_records // 32),
             embeddings=ds.embeddings)
    lats = np.asarray([c.gen_latency_est for c in er.clusters if c.active])
    emit("fig5/nq/gen_cost_median_s", float(np.median(lats)) * 1e6,
         f"p95={np.percentile(lats, 95):.3f};max={lats.max():.3f};"
         f"frac_under_500ms={(lats < 0.5).mean():.3f};"
         f"frac_over_2s={(lats > 2.0).mean():.4f};"
         f"tail_ratio={lats.max()/max(np.median(lats),1e-9):.1f}")
    # the Alg-1 consequence: stored cluster fraction at the paper's SLO
    stored = sum(c.stored for c in er.clusters if c.active)
    emit("fig5/nq/stored_cluster_frac", 0.0,
         f"stored={stored};total={er.nlist};"
         f"storage_mib={er.storage_bytes()/2**20:.1f}")


if __name__ == "__main__":
    run()
