"""Fig. 13 + headline numbers: TTFT for all five Table 4 configurations
across the six BEIR datasets (paper-scale cost model), plus the REAL
laptop-scale pipeline TTFT (reduced models, synthetic corpus).

Paper validation targets: EdgeRAG vs IVF speedup ≈ 1.8x avg / 3.82x large
(abstract) — the paper's own conclusion restates these as 1.22x / 3.69x."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data.synthetic import BEIR_SPECS, scaled_beir
from repro.serving.engine import RAGEngine
from repro.serving.simulator import simulate_ttft

LARGE = ("nq", "hotpotqa", "fever")


def run(n_queries: int = 300, real_records: int = 1500, real_queries: int = 40):
    table = simulate_ttft(n_queries=n_queries)
    speedups = {}
    for ds, rows in table.items():
        for cfg, r in rows.items():
            emit(f"fig13/{ds}/{cfg}/ttft_s", r.mean_ttft_s * 1e6,
                 f"retr_s={r.mean_retrieval_s:.3f};p95_s={r.p95_s:.3f};"
                 f"resident_gib={r.resident_bytes/2**30:.3f};"
                 f"hit={r.cache_hit_rate:.2f};slo={r.slo_hit_rate:.2f}")
        speedups[ds] = rows["ivf"].mean_ttft_s / rows["edgerag"].mean_ttft_s
    avg = float(np.mean(list(speedups.values())))
    large = float(np.mean([speedups[d] for d in LARGE]))
    emit("headline/ttft_speedup_avg", 0.0,
         f"ours={avg:.2f}x;paper_abstract=1.8x;paper_conclusion=1.22x")
    emit("headline/ttft_speedup_large", 0.0,
         f"ours={large:.2f}x;paper_abstract=3.82x;paper_conclusion=3.69x")
    # cache memory overhead (paper: ~7% of system memory)
    er = table["fever"]["edgerag"]
    gen = table["fever"]["ivf_gen"]
    cost = EdgeCostModel()
    emit("headline/cache_memory_overhead", 0.0,
         f"frac_of_system={(er.resident_bytes - gen.resident_bytes)/cost.device_memory_bytes:.3f};paper=0.07")

    # REAL pipeline at laptop scale (relative ordering check)
    ds = scaled_beir("fever", n_records=real_records, n_queries=real_queries)
    cost = EdgeCostModel()
    er_idx = EdgeRAGIndex(ds.embeddings.shape[1], ds.embedder, ds.get_chunks,
                          cost, slo_s=BEIR_SPECS["fever"].slo_s)
    er_idx.build(ds.chunk_ids, ds.texts, nlist=max(32, ds.n // 32),
                 embeddings=ds.embeddings)
    engine = RAGEngine(er_idx, None, cost_model=cost, k=10, nprobe=8)
    ttfts, walls = [], []
    for qi in range(real_queries):
        resp = engine.answer(f"q{qi}", ds.query_embs[qi], ds.get_chunks)
        ttfts.append(resp.ttft_edge_s)
        walls.append(resp.ttft_wall_s)
    emit("real/fever_scaled/edgerag_ttft_edge_s",
         float(np.mean(ttfts)) * 1e6,
         f"wall_ms={np.mean(walls)*1e3:.1f};hit={er_idx.cache.hit_rate:.2f}")


if __name__ == "__main__":
    run()
