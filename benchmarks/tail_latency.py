"""Fig. 12 analogue: retrieval-latency DISTRIBUTION per optimization level
on the nq workload — paper claims: IVF p95 > 64x median (thrashing); +gen
cuts p95 ~4x; +load another ~2x; +cache cuts the rest."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.serving.simulator import EdgeSimulator

CONFIGS = ("ivf", "ivf_gen", "ivf_gen_load", "edgerag")


def run(n_queries: int = 400):
    sim = EdgeSimulator("nq", n_queries=n_queries)
    p95s = {}
    for cfg in CONFIGS:
        r = sim.run(cfg)
        p95s[cfg] = r.p95_s
        emit(f"fig12/nq/{cfg}/p50_s", r.p50_s * 1e6,
             f"p95_s={r.p95_s:.3f};p99_s={r.p99_s:.3f};"
             f"p95_over_p50={r.p95_s/max(r.p50_s, 1e-9):.1f}")
    emit("fig12/nq/p95_reduction_gen_vs_ivf", 0.0,
         f"ratio={p95s['ivf']/max(p95s['ivf_gen'],1e-9):.2f}")
    emit("fig12/nq/p95_reduction_load_vs_gen", 0.0,
         f"ratio={p95s['ivf_gen']/max(p95s['ivf_gen_load'],1e-9):.2f}")
    emit("fig12/nq/p95_reduction_cache_vs_load", 0.0,
         f"ratio={p95s['ivf_gen_load']/max(p95s['edgerag'],1e-9):.2f}")


if __name__ == "__main__":
    run()
