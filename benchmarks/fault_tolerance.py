"""Fault-tolerance benchmark: retrieval quality + tail TTFT under injected
storage faults, stall spikes, and per-request deadlines (core/faults.py
exercised end to end through RAGEngine + RequestScheduler).

One mixed stream (~70% queries, ~30% churn — inserts/removes create the
staleness the degradation ladder's stale-serving rung needs) is replayed
per ARM; arms share the stream, the cost model, and every seed, and differ
only in the deterministic :class:`FaultInjector` wrapped around storage
reads:

  clean        no faults, no stalls (the recall / TTFT baseline)
  f01_stall    1% injected faults (missing / flip / truncate / io) + stalls
  f10_stall    10% injected faults + stalls
  stall_heavy  no faults; heavy-tailed stall spikes only

Every request carries a DEADLINE (scheduler ``slo_s`` = engine
``deadline_s``): the engine reserves prefill headroom and hands the rest
to retrieval, which sheds work down the degradation ladder rather than
blowing the budget.  Reported per arm: p50/p99 TTFT, the scheduler's
outcome mix (met / degraded / missed / failed), retry / degradation /
stale-serve counters, injector + io_stats accounting, and post-stream
recall@10 (faults still active, no deadline pressure) as a ratio against
the clean arm.

Acceptance (criteria block): ZERO unhandled exceptions in every arm,
recall ratio >= 0.99 at the 10% arm (checksum-caught corruption degrades
to regeneration, which is exact), and every injected fault accounted for:
``injected_total == failed_attempts == retries + exhausted`` (each fault
was either retried into a clean read or exhausted into the regen
fallback / degradation path).

``python -m benchmarks.fault_tolerance [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import build_churn_ops, bursty_arrival_times, emit
from repro.core import (DegradationPolicy, EdgeCostModel, EdgeRAGIndex,
                        FaultInjector)
from repro.data import generate_dataset
from repro.serving.engine import RAGEngine
from repro.serving.scheduler import RequestScheduler

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_fault_tolerance.json")

DIM = 48
K = 10
NPROBE = 6
CHURN_FRAC = 0.20
TARGET_UTILIZATION = 0.6
DEADLINE_MULT = 1.5         # request deadline vs calibrated mean query TTFT
# ^ tight enough that expensive queries (regen-heavy, stalled) must shed
#   work to make their deadline — the band the degradation ladder serves
CALIBRATION_FRAC = 0.3

ARMS: Dict[str, Dict] = {
    "clean": dict(fault_rate=0.0, stall_rate=0.0, stall_scale_s=0.0),
    "f01_stall": dict(fault_rate=0.01, stall_rate=0.05, stall_scale_s=0.02),
    "f10_stall": dict(fault_rate=0.10, stall_rate=0.10, stall_scale_s=0.05),
    "stall_heavy": dict(fault_rate=0.0, stall_rate=0.30, stall_scale_s=0.20,
                        stall_sigma=1.5),
    # ablation: stall_heavy with the ladder OFF — what degradation buys
    "stall_heavy_noshed": dict(fault_rate=0.0, stall_rate=0.30,
                               stall_scale_s=0.20, stall_sigma=1.5),
}


def build_ops(ds, rng, churn_frac: float) -> List[Tuple]:
    """Op stream (~70% queries, ~30% churn split insert / remove / update)
    via the shared seeded generator (benchmarks/common.py); inserts and
    updates register on ``ds`` up front so every arm replays the identical
    stream.  Updates are in-place re-embeds (same id, same cluster rows) —
    the same-size staleness the ladder's stale-serving rung covers."""
    n_ins = n_rem = n_upd = int(churn_frac * ds.n / 3)
    n_query = int((n_ins + n_rem + n_upd) * 7 / 3)
    return build_churn_ops(ds, rng, DIM, n_insert=n_ins, n_remove=n_rem,
                           n_update=n_upd, n_query=n_query)


def _fresh_index(ds, cost, *, nlist: int, slo_s: float) -> EdgeRAGIndex:
    er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost, slo_s=slo_s,
                      merge_min_size=2, maintenance="deferred")
    er.build(ds.chunk_ids, ds.texts, nlist=nlist, embeddings=ds.embeddings,
             seed=1)
    for qi in range(len(ds.query_embs)):       # warm cache + threshold
        er.search(ds.query_embs[qi], K, NPROBE)
    return er


def _query_text(ds, qi: int) -> str:
    return "q" * int(ds.query_chars[qi])


def serve_op(eng, er, ds, cost, op, deadline_s=None, policy=None):
    """Apply one op; returns (service_s, response-or-None)."""
    if op[0] == "query":
        qi = op[1]
        resp = eng.answer(_query_text(ds, qi), ds.query_embs[qi],
                          ds.get_chunks, deadline_s=deadline_s,
                          policy=policy)
        return resp.ttft_edge_s, resp
    if op[0] == "insert":
        er.insert(op[1], op[2])
        return (cost.embed_latency(len(op[2]))
                + cost.search_latency(er.nlist, DIM), None)
    if op[0] == "update":
        er.update(op[1], op[2])
        return cost.embed_latency(len(op[2])), None
    er.remove(op[1])
    return cost.search_latency(er.nlist, DIM), None


def calibrate(ds, ops, cost, **index_kw) -> Tuple[float, float, float]:
    """(mean service, mean query TTFT, mean prefill fraction of TTFT) over
    a clean throwaway replay — sizes the arrival gap, the per-request
    deadline, and the policy's prefill reserve for every arm."""
    er = _fresh_index(ds, cost, **index_kw)
    eng = RAGEngine(er, None, cost_model=cost, k=K, nprobe=NPROBE)
    cut = ops[:max(1, int(len(ops) * CALIBRATION_FRAC))]
    total, q_total, frac_total, n_q = 0.0, 0.0, 0.0, 0
    for op in cut:
        s, resp = serve_op(eng, er, ds, cost, op)
        total += s
        if resp is not None:
            q_total += s
            frac_total += resp.prefill_edge_s / max(resp.ttft_edge_s, 1e-12)
            n_q += 1
    return (total / len(cut), q_total / max(n_q, 1),
            frac_total / max(n_q, 1))


def run_arm(ds, stream, cost, injector_kw: Dict, deadline_s: float,
            policy: DegradationPolicy, **index_kw
            ) -> Tuple[EdgeRAGIndex, Dict]:
    er = _fresh_index(ds, cost, **index_kw)
    injector = FaultInjector(seed=99, **injector_kw)
    faulty = injector.fault_rate > 0 or injector.stall_rate > 0
    er.storage.faults = injector if faulty else None
    # maintenance (restore/split/merge after churn) runs ONLY in idle gaps
    # (scheduler maintenance_fn): drain ownership is EXTERNAL, so the
    # engine never drains after decode — under backlog, staleness
    # accumulates and queries pay regeneration, the deadline pressure the
    # ladder sheds.  (The old maintenance_budget_s=0.0 still executed one
    # op per answer — a double drain alongside the scheduler hook.)
    eng = RAGEngine(er, None, cost_model=cost, k=K, nprobe=NPROBE,
                    maintenance_owner="external")
    sched = RequestScheduler()
    op_of = {}
    for t, op in stream:
        op_of[sched.submit(t, slo_s=deadline_s).rid] = op
    counters = {"retries": 0, "degraded_clusters": 0, "stale_served": 0,
                "stall_s": 0.0, "backoff_s": 0.0}
    unhandled = 0

    def serve(req) -> float:
        op = op_of[req.rid]
        # the deadline the ENGINE gets is what is left of the request's SLO
        # after queueing delay — under backlog the ladder sheds work instead
        # of serving a full-quality answer nobody is waiting for
        dl = None
        if op[0] == "query":
            dl = max(req.slo_s - (req.start_s - req.arrival_s),
                     0.05 * req.slo_s)
        service, resp = serve_op(eng, er, ds, cost, op, deadline_s=dl,
                                 policy=policy)
        if resp is not None:
            req.degraded = resp.outcome == "degraded"
            counters["retries"] += resp.retries
            counters["degraded_clusters"] += resp.degraded_clusters
            counters["stale_served"] += resp.stale_served
            counters["stall_s"] += resp.retrieval.l2_stall_s
            counters["backoff_s"] += resp.retrieval.l2_retry_backoff_s
        return service

    try:
        sched.run(serve,
                  maintenance_fn=lambda gap: er.maintenance.drain(gap).edge_s)
    except Exception:       # noqa: BLE001 — the stack must never throw
        unhandled += 1
        raise
    # the scheduler's last-resort catch also counts as unhandled BY THE
    # RETRIEVAL STACK: the fault model is supposed to absorb faults below it
    unhandled += len(sched.errors)
    er.maintenance.drain(None)
    ttfts = np.array([r.latency_s for r in sched.completed
                      if op_of[r.rid][0] == "query"])
    quarantined = er.maintenance.stats()["quarantined"]
    return er, {
        "n_query_reqs": int(len(ttfts)),
        "p50_ttft_s": float(np.percentile(ttfts, 50)),
        "p99_ttft_s": float(np.percentile(ttfts, 99)),
        "mean_ttft_s": float(ttfts.mean()),
        "outcomes": sched.outcome_counts(),
        "degradation": dict(counters),
        "injected": injector.stats(),
        "io_stats": dict(er.storage.io_stats),
        "maintenance_quarantined": int(quarantined),
        "unhandled_exceptions": int(unhandled),
    }


def recall_at_k(er, ds, live: set) -> float:
    """Post-stream recall sweep — faults stay ACTIVE, no deadline pressure
    (the fault model must recover exactly, not approximately)."""
    ids, _, _ = er.search_batch(ds.query_embs, K, NPROBE)
    hits = 0
    for qi in range(len(ds.query_embs)):
        hits += len(set(int(i) for i in ids[qi] if i >= 0)
                    & (ds.relevant(qi) & live))
    return hits / (len(ds.query_embs) * K)


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_records = 500 if quick else 1600
    nq = 24 if quick else 64
    nlist = max(16, n_records // 30)
    ds = generate_dataset(n_records=n_records, dim=DIM,
                          n_topics=max(12, n_records // 60),
                          n_queries=nq, seed=17)
    cost = EdgeCostModel()
    # small SLO: the heavy tail is stored, so storage reads (the fault
    # surface) dominate resolution
    mean_cluster_chars = sum(len(t) for t in ds.texts) / nlist
    slo_s = cost.embed_latency(int(0.5 * mean_cluster_chars))
    index_kw = dict(nlist=nlist, slo_s=slo_s)
    rng = np.random.default_rng(23)
    ops = build_ops(ds, rng, CHURN_FRAC)
    mean_service_s, mean_query_s, prefill_frac = calibrate(
        ds, ops, cost, **index_kw)
    gap_mean_s = mean_service_s / TARGET_UTILIZATION
    deadline_s = DEADLINE_MULT * mean_query_s
    # reserve the MEASURED prefill share of TTFT (prefill is not sheddable)
    # so the remainder handed to retrieval is an honest budget
    policy = DegradationPolicy(
        prefill_reserve_frac=min(0.9, prefill_frac))
    times = bursty_arrival_times(rng, len(ops), gap_mean_s)
    stream = list(zip(times, ops))
    emit("fault_tolerance.calibration", gap_mean_s * 1e6,
         f"gap={gap_mean_s*1e3:.1f}ms deadline={deadline_s*1e3:.1f}ms "
         f"prefill_frac={prefill_frac:.2f}")

    arms: Dict[str, Dict] = {}
    recalls: Dict[str, float] = {}
    for name, injector_kw in ARMS.items():
        pol = policy
        if name.endswith("_noshed"):
            pol = DegradationPolicy(
                shed_probes=False, shed_regen=False, serve_stale=False,
                prefill_reserve_frac=policy.prefill_reserve_frac)
        er, cell = run_arm(ds, stream, cost, injector_kw, deadline_s,
                           pol, **index_kw)
        live = set(er._chunk_cluster)
        recalls[name] = recall_at_k(er, ds, live)
        cell["recall_at10"] = recalls[name]
        arms[name] = cell
        o = cell["outcomes"]
        emit(f"fault_tolerance.{name}", cell["p99_ttft_s"] * 1e6,
             f"p99={cell['p99_ttft_s']*1e3:.1f}ms "
             f"met={o['met']} deg={o['degraded']} miss={o['missed']} "
             f"fail={o['failed']} inj={cell['injected']['injected_total']} "
             f"recall@10={recalls[name]:.3f}")

    ratios = {name: recalls[name] / max(recalls["clean"], 1e-12)
              for name in ARMS}
    accounted = {}
    for name, cell in arms.items():
        st = cell["io_stats"]
        accounted[name] = (
            cell["injected"]["injected_total"] == st["failed_attempts"]
            == st["retries"] + st["exhausted"])
    results = {
        "n_records": n_records, "n_queries": nq, "nlist": nlist,
        "k": K, "nprobe": NPROBE, "slo_s": slo_s,
        "gap_mean_s": gap_mean_s, "deadline_s": deadline_s,
        "prefill_reserve_frac": policy.prefill_reserve_frac,
        "churn_frac": CHURN_FRAC,
        "arms": arms,
        "recall_ratio_vs_clean": ratios,
        "criteria": {
            "zero_unhandled_exceptions": all(
                c["unhandled_exceptions"] == 0 for c in arms.values()),
            "recall_ratio_f10_ok": ratios["f10_stall"] >= 0.99,
            "all_faults_accounted": all(accounted.values()),
            "ladder_reduces_p99": (
                arms["stall_heavy"]["p99_ttft_s"]
                <= arms["stall_heavy_noshed"]["p99_ttft_s"]),
        },
    }
    ok = all(results["criteria"].values())
    print(f"# zero unhandled exceptions, f10 recall ratio >= 0.99, "
          f"all faults accounted, ladder reduces stall_heavy p99: "
          f"{'PASS' if ok else 'FAIL'}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
