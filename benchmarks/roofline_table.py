"""§Roofline table: reads experiments/dryrun/*.json (produced by
launch/dryrun.py) and emits one row per (arch × shape × mesh) baseline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__),
                          "..", "experiments", "dryrun")


def run():
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline/NO_DRYRUN_RESULTS", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        r = d["roofline"]
        tag = f"__{d['tag']}" if d.get("tag") else ""
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}{tag}/bound_step_ms",
             r["bound_step_ms"] * 1e3,
             f"dominant={r['dominant']};compute_ms={r['compute_ms']:.3f};"
             f"memory_ms={r['memory_ms']:.3f};"
             f"collective_ms={r['collective_ms']:.3f};"
             f"useful_ratio={r['useful_ratio']:.3f};"
             f"mfu_at_bound={r['mfu_at_bound']:.4f}")


if __name__ == "__main__":
    run()
