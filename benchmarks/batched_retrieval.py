"""Batched retrieval fast-path benchmark: ``search_batch`` vs a sequential
per-query ``search`` loop.

Sweeps batch size × nprobe on a synthetic Zipf-reuse corpus embedded with
the real :class:`HashingEmbedder` (regeneration compute is genuine work, so
cross-query cluster dedup and the single coalesced embed call show up in
wall-clock QPS).  Reports per cell: QPS, speedup over sequential batch-1,
cross-query cluster-dedup rate, and embed_fn call count, and writes the
whole grid as JSON (default: ``BENCH_retrieval.json`` at the repo root) so
the perf trajectory is tracked across PRs.

``python -m benchmarks.batched_retrieval [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import generate_dataset
from repro.data.embedder import HashingEmbedder

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_retrieval.json")

DIM = 64
K = 10


def _corpus(n_records: int, n_queries: int, seed: int = 0):
    """Texts with Zipf topic reuse; queries are perturbed member chunks of
    Zipf-sampled topics, embedded in the same hashing space as the index."""
    ds = generate_dataset(n_records=n_records, dim=DIM,
                          n_topics=max(16, n_records // 60),
                          n_queries=n_queries, seed=seed)
    embedder = HashingEmbedder(dim=DIM, seed=7, n_features=2048)
    corpus_embs = embedder.embed(ds.texts)
    rng = np.random.default_rng(seed + 1)
    q_texts = []
    for t in ds.query_topic:
        members = np.where(ds.topic_of_chunk == t)[0]
        q_texts.append(ds.texts[int(rng.choice(members))])
    query_embs = embedder.embed(q_texts)
    store = {int(i): txt for i, txt in zip(ds.chunk_ids, ds.texts)}
    get_chunks = lambda ids: [store[int(i)] for i in ids]
    return ds, embedder, corpus_embs, query_embs, get_chunks


def _fresh_index(ds, embedder, corpus_embs, get_chunks, nlist: int,
                 **kw) -> EdgeRAGIndex:
    er = EdgeRAGIndex(DIM, embedder, get_chunks, EdgeCostModel(), **kw)
    er.build(ds.chunk_ids, ds.texts, nlist=nlist, embeddings=corpus_embs,
             seed=1)
    return er


def _sweep(ds, embedder, corpus_embs, query_embs, get_chunks, nlist: int,
           nprobe: int, batch_sizes, index_kw: Dict) -> List[Dict]:
    nq = len(query_embs)
    cells = []
    # sequential batch-1 baseline
    er = _fresh_index(ds, embedder, corpus_embs, get_chunks, nlist,
                      **index_kw)
    calls0 = embedder.calls
    t0 = time.perf_counter()
    for qi in range(nq):
        er.search(query_embs[qi], K, nprobe)
    seq_elapsed = time.perf_counter() - t0
    seq_qps = nq / seq_elapsed
    cells.append(dict(nprobe=nprobe, batch=1, mode="sequential",
                      qps=seq_qps, speedup=1.0, dedup_rate=0.0,
                      embed_calls=embedder.calls - calls0))
    for b in batch_sizes:
        er = _fresh_index(ds, embedder, corpus_embs, get_chunks, nlist,
                          **index_kw)
        calls0 = embedder.calls
        probed = shared = 0
        t0 = time.perf_counter()
        for lo in range(0, nq, b):
            _, _, lats = er.search_batch(query_embs[lo:lo + b], K, nprobe)
            probed += sum(l.n_clusters_probed for l in lats)
            shared += sum(l.n_shared_hits for l in lats)
        elapsed = time.perf_counter() - t0
        cells.append(dict(
            nprobe=nprobe, batch=b, mode="batched", qps=nq / elapsed,
            speedup=(nq / elapsed) / seq_qps,
            dedup_rate=shared / max(1, probed),
            embed_calls=embedder.calls - calls0))
    return cells


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_records = 1500 if quick else 3000
    nq = 64 if quick else 128
    nlist = max(16, n_records // 60)
    ds, embedder, corpus_embs, query_embs, get_chunks = _corpus(
        n_records, nq)
    results = {"n_records": n_records, "n_queries": nq, "nlist": nlist,
               "k": K, "configs": {}}
    configs = {
        # pure online regeneration: every probe regenerates — isolates the
        # dedup + coalesced-embed win (Table 4 'IVF+Embed.Gen.' row)
        "embed_gen": dict(store_heavy=False, cache_bytes=0),
        # full EdgeRAG: selective storage + adaptive cache on top
        "edgerag": dict(slo_s=0.3, store_heavy=True, cache_bytes=1 << 20),
    }
    batch_sizes = (4, 16) if quick else (4, 8, 16)
    for cfg_name, kw in configs.items():
        cfg_cells = []
        for nprobe in (4, 8):
            cfg_cells += _sweep(ds, embedder, corpus_embs, query_embs,
                                get_chunks, nlist, nprobe, batch_sizes, kw)
        results["configs"][cfg_name] = cfg_cells
        for c in cfg_cells:
            emit(f"batched_retrieval.{cfg_name}.np{c['nprobe']}.b{c['batch']}",
                 1e6 / c["qps"],
                 f"qps={c['qps']:.1f} speedup={c['speedup']:.2f}x "
                 f"dedup={c['dedup_rate']:.2f} embed_calls={c['embed_calls']}")
    b16 = [c for c in results["configs"]["embed_gen"]
           if c["batch"] == 16 and c["nprobe"] == 8]
    if b16:
        results["batch16_speedup_np8"] = b16[0]["speedup"]
        print(f"# batch-16 vs sequential speedup (embed_gen, nprobe=8): "
              f"{b16[0]['speedup']:.2f}x")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
