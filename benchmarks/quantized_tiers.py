"""Quantized storage tiers: recall@10 + edge TTFT per storage codec.

Builds the same corpus under each storage codec (fp32 / fp16 / int8) with a
tiny SLO so (nearly) every cluster lands in selective storage, then measures
against the fp32 baseline:

  * recall@10 vs the corpus's ground-truth topics, and the ratio to fp32
    (acceptance: >= 0.95);
  * retrieved-id overlap with the fp32 tier;
  * storage bytes + reduction factor (fp16 exactly 2x; int8 ~3.9x — per-row
    fp16 scales cost 2 B against 4·d B of fp32 rows, so 4x is the asymptote);
  * mean edge TTFT (retrieval + prefill via the cost model) — quantized
    loads stream fewer bytes off the SD card, minus a dequant term.

The cost model is pinned to the paper's bandwidth-constrained regime (slow
SD-card sequential reads under memory pressure, few large clusters, a short
prompt) so the byte-proportional part of the storage load — the term the
codecs shrink — dominates the per-cluster seek and the prefill; at the
default calibration the seek constant hides the reduction at this corpus
scale.

Appends the grid to the BENCH trajectory as ``BENCH_quantized_tiers.json``.

``python -m benchmarks.quantized_tiers [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

import numpy as np

from benchmarks.common import emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import generate_dataset

# Pinned to the dense-payload codecs this grid was designed around; the pq
# codec gets its own disk-native memmap benchmark (benchmarks/pq_tier.py).
DENSE_CODECS = ("fp32", "fp16", "int8")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_quantized_tiers.json")

DIM = 64
K = 10
NPROBE = 6
PROMPT_TOKENS = 32


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_records = 1200 if quick else 3000
    nq = 48 if quick else 128
    nlist = max(8, n_records // 250)          # few, heavy clusters
    ds = generate_dataset(n_records=n_records, dim=DIM,
                          n_topics=max(16, n_records // 60),
                          n_queries=nq, seed=9)
    # SD card under memory pressure (paper §3.2): bandwidth-bound reads
    cost = EdgeCostModel(storage_seq_bw_bytes_per_sec=2e6,
                         storage_seek_s=0.002)
    results: Dict = {"n_records": n_records, "n_queries": nq,
                     "nlist": nlist, "k": K, "codecs": {}}
    ids_by_codec: Dict[str, np.ndarray] = {}
    for codec in DENSE_CODECS:
        # tiny SLO + no cache: every search exercises the storage tier
        er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost,
                          slo_s=1e-6, store_heavy=True, cache_bytes=0,
                          storage_codec=codec)
        er.build(ds.chunk_ids, ds.texts, nlist=nlist,
                 embeddings=ds.embeddings, seed=1)
        # per-query searches: each query pays its own storage loads (the
        # single-user serving scenario; one big batch would dedup them away)
        ids_rows, lats = [], []
        for qi in range(nq):
            row, _, lat = er.search(ds.query_embs[qi], K, NPROBE)
            ids_rows.append(row[0])
            lats.append(lat)
        ids = np.stack(ids_rows)
        ids_by_codec[codec] = ids
        hits = sum(len(set(ids[qi].tolist()) & ds.relevant(qi))
                   for qi in range(nq))
        recall = hits / (nq * K)
        ttft = float(np.mean([l.retrieval_s
                              + cost.prefill_latency(PROMPT_TOKENS)
                              for l in lats]))
        st = er.stats()
        assert st["stored_clusters"] == st["active_clusters"]
        results["codecs"][codec] = {
            "recall_at10": recall,
            "ttft_edge_s": ttft,
            "storage_bytes": st["storage_bytes"],
            "storage_fp32_bytes": st["storage_fp32_bytes"],
            "reduction": st["storage_fp32_bytes"] / st["storage_bytes"],
            "n_storage_loads": sum(l.n_storage_loads for l in lats),
        }
    fp32 = results["codecs"]["fp32"]
    for codec in DENSE_CODECS:
        cell = results["codecs"][codec]
        cell["recall_ratio_vs_fp32"] = (cell["recall_at10"]
                                        / max(fp32["recall_at10"], 1e-12))
        cell["id_overlap_vs_fp32"] = float(np.mean([
            len(set(ids_by_codec[codec][qi].tolist())
                & set(ids_by_codec["fp32"][qi].tolist())) / K
            for qi in range(nq)]))
        cell["ttft_speedup_vs_fp32"] = fp32["ttft_edge_s"] / cell["ttft_edge_s"]
        emit(f"quantized_tiers.{codec}", cell["ttft_edge_s"] * 1e6,
             f"recall@10={cell['recall_at10']:.3f} "
             f"ratio={cell['recall_ratio_vs_fp32']:.3f} "
             f"reduction={cell['reduction']:.2f}x "
             f"ttft_speedup={cell['ttft_speedup_vs_fp32']:.2f}x")
    ok = all(results["codecs"][c]["recall_ratio_vs_fp32"] >= 0.95
             for c in ("fp16", "int8"))
    results["recall_criterion_met"] = ok
    print(f"# recall@10 >= 0.95 of fp32 for fp16+int8: "
          f"{'PASS' if ok else 'FAIL'}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
