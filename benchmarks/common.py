"""Shared benchmark helpers: CSV emission, timing, and the seeded
churn-stream / arrival-trace generators used by the serving benchmarks
(online_churn, fault_tolerance, pipeline_throughput)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np


def emit(name: str, us_per_call: float, derived: str = ""):
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Returns mean microseconds per call."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def build_churn_ops(ds, rng, dim: int, *, n_insert: int, n_remove: int,
                    n_query: int, n_update: int = 0,
                    insert_noise: float = 0.05, update_noise: float = 0.02,
                    first_new_id: int = 1_000_000) -> List[Tuple]:
    """One seeded mixed query / churn op stream, shared by the serving
    benchmarks.  Op kinds are counted out, shuffled, then materialized in
    shuffled order: inserts synthesize a near-duplicate of a random corpus
    chunk (plus ``insert_noise``), updates re-embed a random LIVE chunk in
    place (same id, ``update_noise``), removes pick a random live chunk,
    queries pick a random query index.  Inserts and updates register their
    text/embedding on ``ds`` up front so calibration and every arm replay
    the IDENTICAL stream.

    Returns op payloads without timestamps (pair with
    :func:`bursty_arrival_times`): ``("insert", id, text)``,
    ``("update", id, text)``, ``("remove", id)``, ``("query", qi)``.
    """
    live = [int(i) for i in ds.chunk_ids]
    next_id = first_new_id
    kinds = (["insert"] * n_insert + ["remove"] * n_remove
             + ["update"] * n_update + ["query"] * n_query)
    rng.shuffle(kinds)
    ops: List[Tuple] = []
    for kind in kinds:
        if kind == "insert":
            src = int(rng.integers(ds.n))
            emb = (ds.embeddings[src]
                   + insert_noise * rng.standard_normal(dim))
            emb = (emb / np.linalg.norm(emb)).astype(np.float32)
            text = f"doc-{next_id} " + "tok " * int(rng.integers(3, 60))
            ds.add_chunk(next_id, text, emb)
            ops.append(("insert", next_id, text))
            live.append(next_id)
            next_id += 1
        elif kind == "remove" and live:
            ops.append(("remove", live.pop(int(rng.integers(len(live))))))
        elif kind == "update" and live:
            cid = live[int(rng.integers(len(live)))]
            emb = (ds.embedder.table[cid]
                   + update_noise * rng.standard_normal(dim))
            emb = (emb / np.linalg.norm(emb)).astype(np.float32)
            text = f"doc-{cid} rev " + "tok " * int(rng.integers(3, 60))
            ds.add_chunk(cid, text, emb)        # same id: in-place
            ops.append(("update", cid, text))
        else:
            ops.append(("query", int(rng.integers(len(ds.query_embs)))))
    return ops


def bursty_arrival_times(rng, n: int, gap_mean_s: float, *,
                         burst: int = 1,
                         burst_gap_frac: float = 0.1) -> List[float]:
    """``n`` arrival timestamps at mean rate ``1/gap_mean_s``.

    ``burst=1``: plain exponential (Poisson) arrivals.  ``burst>1``: the
    conversational edge pattern — ``burst`` back-to-back ops separated by
    ``burst_gap_frac * gap_mean_s``, then a lull sized so the MEAN rate is
    unchanged (maintenance drains in the lulls, queries queue in the
    bursts)."""
    if burst <= 1:
        times, t = [], 0.0
        for _ in range(n):
            t += float(rng.exponential(gap_mean_s))
            times.append(t)
        return times
    intra_s = burst_gap_frac * gap_mean_s
    lull_s = burst * gap_mean_s - (burst - 1) * intra_s
    times, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(lull_s if i % burst == 0 else intra_s))
        times.append(t)
    return times
