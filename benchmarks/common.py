"""Shared benchmark helpers: CSV emission + timing."""
from __future__ import annotations

import time
from typing import Callable


def emit(name: str, us_per_call: float, derived: str = ""):
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Returns mean microseconds per call."""
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6
