"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle wall time.

Interpret-mode numbers are CORRECTNESS-path timings on CPU — the TPU perf
story lives in the §Roofline analysis; these rows exist to (a) regression-
track the op dispatch overhead and (b) keep a measured record that the jnp
fallback is the right CPU default."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ivf_topk.kernel import topk_ip_pallas
from repro.kernels.ivf_topk.ref import topk_ip_ref
import jax

RNG = np.random.default_rng(0)


def _r(shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


def run():
    # ivf_topk: the retrieval hot loop
    for n in (1_000, 10_000):
        embs, q = _r((n, 768)), _r((1, 768))
        ref = jax.jit(lambda e, qq: topk_ip_ref(e, qq, 10))
        us_ref = time_fn(lambda: jax.block_until_ready(ref(embs, q)))
        us_pal = time_fn(lambda: jax.block_until_ready(
            topk_ip_pallas(embs, q, 10, interpret=True)), iters=2)
        emit(f"kernels/ivf_topk/n{n}/ref_jit", us_ref,
             f"pallas_interpret_us={us_pal:.0f}")

    # flash attention prefill block
    q, k, v = _r((1, 8, 512, 64)), _r((1, 2, 512, 64)), _r((1, 2, 512, 64))
    ref = jax.jit(lambda a, b, c: flash_attention_ref(a, b, c))
    us = time_fn(lambda: jax.block_until_ready(ref(q, k, v)))
    emit("kernels/flash_attention/s512_h8_gqa4/ref_jit", us,
         "pallas_validated_in_tests=true")

    # decode attention vs 32k cache
    qd = _r((4, 8, 64))
    kc, vc = _r((4, 4096, 2, 64)), _r((4, 4096, 2, 64))
    refd = jax.jit(lambda a, b, c: decode_attention_ref(a, b, c, 4096))
    us = time_fn(lambda: jax.block_until_ready(refd(qd, kc, vc)))
    emit("kernels/decode_attention/cache4k/ref_jit", us,
         "pallas_validated_in_tests=true")


if __name__ == "__main__":
    run()
