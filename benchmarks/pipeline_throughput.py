"""Pipeline-throughput benchmark: staged serving vs the sequential loop.

The same closed-loop query workload (batches of ``BATCH`` queries, all
available at t=0 — steady-state throughput, not open-loop tails) is served
twice against indexes built and churned identically:

  sequential  the status-quo loop: each batch runs S1→S4 back-to-back and
              then drains the deferred-maintenance queue to quiescence
              (engine-owned drain) before the next batch starts — retrieval,
              decode, and maintenance all serialize on the modeled clock
  pipelined   :class:`~repro.serving.pipeline.StagedPipeline`: batch N+1's
              probe / fetch / score run while batch N decodes, and the
              maintenance queue drains inside residual S2/S3 bubbles
              (strict budgets), with one final drain after the last decode

Before serving, an update-only churn pass (shared seeded generator,
``benchmarks/common.py``) re-embeds a fraction of the corpus in place:
same ids, same cluster membership — so both arms probe IDENTICAL cluster
sets — while staling every touched cluster's stored copy and seeding the
maintenance queue with the restore / drop work the pipelined arm must hide
in bubbles.  Update-only churn is what keeps the cross-arm bit-identicality
claim testable: insert/remove churn would let maintenance timing change
membership and thus probe sets.

Retrieval work is regeneration-dominated (``cache_bytes=0``, most clusters
under the storage SLO): per-batch retrieval is a stable fraction of decode
time, the regime where pipelining pays (RAGDoll, arXiv 2504.15302).

Reported: modeled makespan + QPS per arm, the pipelined arm's full
:class:`PipelineTrace` (per-stage busy seconds, queue depths, maintenance
in bubbles, replans, hidden-retrieval fraction), per-arm recall@K, and a
per-query chunk-id comparison.  Acceptance (full scale): retrieval >= 90%
hidden under decode, pipelined QPS >= 1.5x sequential, chunk ids
bit-identical across arms.  At ``--quick`` scale fewer batches amortize
the pipeline ramp so the smoke criterion is only "pipelined not slower".

``python -m benchmarks.pipeline_throughput [--out PATH] [--quick]``
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import build_churn_ops, emit
from repro.core import EdgeCostModel, EdgeRAGIndex
from repro.data import generate_dataset
from repro.serving.engine import RAGEngine
from repro.serving.pipeline import PipelineBatch, StagedPipeline

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_pipeline.json")

DIM = 48
K = 2                    # short contexts: decode must not dwarf retrieval
NPROBE = 10
MAX_NEW_TOKENS = 24
BATCH = 8
UPDATE_FRAC = 0.4        # corpus fraction re-embedded in place before serving


def _query_text(ds, qi: int) -> str:
    return "q" * int(ds.query_chars[qi])


def _build_index(ds, cost, *, nlist: int, slo_s: float) -> EdgeRAGIndex:
    er = EdgeRAGIndex(DIM, ds.embedder, ds.get_chunks, cost, slo_s=slo_s,
                      cache_bytes=0, merge_min_size=2,
                      maintenance="deferred")
    er.build(ds.chunk_ids, ds.texts, nlist=nlist, embeddings=ds.embeddings,
             seed=1)
    return er


def _apply_churn(er, ops, cost) -> float:
    """Replay the update-only churn against a fresh index; returns its
    modeled edge seconds (identical both arms — charged before serving)."""
    total = 0.0
    for op in ops:
        assert op[0] == "update", "pipeline bench churn must be update-only"
        er.update(op[1], op[2])
        total += cost.embed_latency(len(op[2]))
    return total


def _batches(ds, n_batches: int) -> List[Tuple[List[str], np.ndarray]]:
    nq = len(ds.query_embs)
    out = []
    for b in range(n_batches):
        idx = [(b * BATCH + i) % nq for i in range(BATCH)]
        out.append(([_query_text(ds, qi) for qi in idx],
                    np.stack([ds.query_embs[qi] for qi in idx])))
    return out


def run_sequential(ds, ops, cost, batches, **index_kw) -> Dict:
    er = _build_index(ds, cost, **index_kw)
    _apply_churn(er, ops, cost)
    eng = RAGEngine(er, None, cost_model=cost, k=K, nprobe=NPROBE,
                    max_new_tokens=MAX_NEW_TOKENS,
                    maintenance_owner="engine")
    clock = 0.0
    maintenance_s = 0.0
    retrieval_s = 0.0
    decode_s = 0.0
    ids: List[List[int]] = []
    for queries, embs in batches:
        job = eng.make_job(queries, embs, ds.get_chunks)
        eng.stage_plan(job)
        eng.stage_fetch(job)
        eng.stage_score(job)
        eng.stage_decode(job)
        drain = (er.maintenance.drain(None).edge_s
                 if len(er.maintenance) else 0.0)
        retr = sum(job.stage_edge_s[s] for s in ("s1", "s2", "s3"))
        retrieval_s += retr
        decode_s += job.stage_edge_s["s4"]
        maintenance_s += drain
        clock += retr + job.stage_edge_s["s4"] + drain
        ids.extend(r.chunk_ids for r in eng.finalize(job))
    n_queries = sum(len(q) for q, _ in batches)
    return {"makespan_s": clock, "qps": n_queries / clock,
            "retrieval_s": retrieval_s, "decode_s": decode_s,
            "maintenance_s": maintenance_s, "ids": ids}


def run_pipelined(ds, ops, cost, batches, **index_kw) -> Dict:
    er = _build_index(ds, cost, **index_kw)
    _apply_churn(er, ops, cost)
    eng = RAGEngine(er, None, cost_model=cost, k=K, nprobe=NPROBE,
                    max_new_tokens=MAX_NEW_TOKENS,
                    maintenance_owner="external")   # the pipeline drains
    pipe = StagedPipeline(eng, ds.get_chunks)
    responses, trace = pipe.run(
        [PipelineBatch(queries=q, query_embs=e) for q, e in batches])
    ids = [r.chunk_ids for batch in responses for r in batch]
    # the final drain delays no response, but the work is real — charge it
    # to the makespan so the throughput comparison is honest
    total = trace.makespan_s + trace.final_drain_s
    return {"makespan_s": trace.makespan_s,
            "final_drain_s": trace.final_drain_s,
            "qps": trace.n_queries / total,
            "trace": trace.as_dict(), "ids": ids}


def recall_at_k(ds, batches, ids: List[List[int]]) -> float:
    nq = len(ds.query_embs)
    hits, total = 0, 0
    qi_seq = [(b * BATCH + i) % nq
              for b in range(len(batches)) for i in range(BATCH)]
    for qi, got in zip(qi_seq, ids):
        hits += len(set(got) & ds.relevant(qi))
        total += K
    return hits / total


def run(out_path: str = DEFAULT_OUT, quick: bool = False) -> Dict:
    n_records = 600 if quick else 1400
    nq = 32 if quick else 64
    n_batches = 8 if quick else 16
    nlist = max(16, n_records // 30)
    ds = generate_dataset(n_records=n_records, dim=DIM,
                          n_topics=max(12, n_records // 60),
                          n_queries=nq, seed=17)
    cost = EdgeCostModel()
    # most clusters regenerate (the EdgeRAG fast path); the heavy tail is
    # stored so update churn seeds restore work for the bubbles
    mean_cluster_chars = sum(len(t) for t in ds.texts) / nlist
    slo_s = cost.embed_latency(int(1.15 * mean_cluster_chars))
    index_kw = dict(nlist=nlist, slo_s=slo_s)
    rng = np.random.default_rng(23)
    ops = build_churn_ops(ds, rng, DIM, n_insert=0, n_remove=0,
                          n_update=int(UPDATE_FRAC * ds.n), n_query=0)
    batches = _batches(ds, n_batches)

    seq = run_sequential(ds, ops, cost, batches, **index_kw)
    pipe = run_pipelined(ds, ops, cost, batches, **index_kw)
    ids_identical = seq["ids"] == pipe["ids"]
    recall = recall_at_k(ds, batches, pipe["ids"])
    seq_ids = seq.pop("ids")
    recall_seq = recall_at_k(ds, batches, seq_ids)
    pipe.pop("ids")
    qps_ratio = pipe["qps"] / seq["qps"]
    hidden = pipe["trace"]["hidden_retrieval_fraction"]

    emit("pipeline.sequential", seq["makespan_s"] * 1e6,
         f"qps={seq['qps']:.3f} maint={seq['maintenance_s']:.2f}s")
    emit("pipeline.pipelined", pipe["makespan_s"] * 1e6,
         f"qps={pipe['qps']:.3f} hidden={hidden:.3f} "
         f"bubbles_maint={pipe['trace']['maintenance_in_bubbles_s']:.2f}s "
         f"replans={pipe['trace']['replans']}")
    emit("pipeline.speedup", qps_ratio * 1e6,
         f"qps_ratio={qps_ratio:.2f} ids_identical={ids_identical}")

    results = {
        "n_records": n_records, "n_queries_corpus": nq, "nlist": nlist,
        "dim": DIM, "k": K, "nprobe": NPROBE, "slo_s": slo_s,
        "batch": BATCH, "n_batches": n_batches,
        "max_new_tokens": MAX_NEW_TOKENS,
        "update_frac": UPDATE_FRAC, "n_updates": len(ops),
        "sequential": seq,
        "pipelined": pipe,
        "qps_ratio": qps_ratio,
        "hidden_retrieval_fraction": hidden,
        "ids_identical": ids_identical,
        "recall_at_k": {"pipelined": recall, "sequential": recall_seq},
        "criteria": {
            # full-scale targets; --quick runs fewer batches, so the CI
            # smoke lane only enforces pipelined_not_slower + ids
            "retrieval_hidden_90": hidden >= 0.90,
            "qps_ratio_1_5": qps_ratio >= 1.5,
            "ids_identical": ids_identical,
            "pipelined_not_slower": qps_ratio >= 1.0,
            "steady_state_batch_8": BATCH >= 8,
        },
    }
    ok = all(results["criteria"].values())
    print(f"# retrieval >= 90% hidden, qps >= 1.5x sequential, ids "
          f"bit-identical: {'PASS' if ok else 'FAIL'}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_path}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(args.out, args.quick)


if __name__ == "__main__":
    main()
